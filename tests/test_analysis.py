"""repro.analysis — every rule fires on an injected violation, and the
shipped tree is clean.

Three kinds of injection, one per pass group:
  * contract rules: monkeypatch the engine's paged-DMA protocol (or
    doctor a captured _Launch) and re-run the recording-shim sweep;
  * lint rules: synthesized snippet files under tmp_path, fed through
    ``run_lint(root, files=[...])``;
  * drift rules: a fake registry family / a doctored docs copy against
    the real artifacts.

The clean-tree smoke at the end pins the acceptance criterion: zero
findings, zero suppressions, byte-stable JSON.
"""
import dataclasses
import textwrap

import jax.numpy as jnp
import pytest

import repro.analysis as analysis
from repro.analysis import cases, contracts, core, drift, lint
from repro.analysis.contracts import Point
from repro.kernels import stream_fused

pl = stream_fused.pl
pltpu = stream_fused.pltpu

PAGED_STACKED = Point("stacked", "hbm_paged", 2, cases.TD)


def _only(findings, rule):
    """Assert the given rule fired exactly once; return that finding."""
    hits = [f for f in findings if f.rule == rule]
    assert len(hits) == 1, (rule, [f.message for f in findings])
    return hits[0]


def _rules(findings):
    return {f.rule for f in findings}


# ===================================================== contract passes ==

def test_contracts_clean_sweep():
    """The shipped registry passes the full contract sweep."""
    assert contracts.run_contracts() == []


def test_dma_unpaired_start_fires(monkeypatch):
    """stage_in that starts its copy but never waits -> one finding."""
    def bad_stage_in(self, i):
        sm = self.meta.states[i]
        sem = self._scr[sm.sem_idx].at[self.meta.depth]
        cp = stream_fused._async_copy(
            self._read_view(i, self.blk), self._scr[sm.scr_idx], sem,
            op="stage_in", state=i)
        cp.start()  # wait() dropped: the DMA is in flight at slot reuse

    monkeypatch.setattr(stream_fused._Engine, "stage_in", bad_stage_in)
    findings = contracts.run_contracts(points=[PAGED_STACKED])
    f = _only(findings, "dma-unpaired-start")
    assert "stage_in" in f.message and "never waited" in f.message


def test_dma_ring_order_fires(monkeypatch):
    """A ring that eagerly starts every window reuses slots while their
    previous copy is outstanding (visible at depth < n_windows)."""
    def bad_paged_fill(self, i, fill):
        sm = self.meta.states[i]
        ring, sems = self._scr[sm.ring_idx], self._scr[sm.sem_idx]
        depth, n_win, dmas = self.meta.depth, self.n_dblocks, {}
        for w in range(n_win):  # all upfront: slot w%depth reused hot
            dma = stream_fused._async_copy(
                self._read_view(i, pl.ds(w * self.td, self.td)),
                ring.at[w % depth], sems.at[w % depth],
                op="ring", state=i, window=w, slot=w % depth)
            dma.start()
            dmas[w] = dma
        for w in range(n_win):
            dmas.pop(w).wait()
            fill(w, pl.ds(w * self.td, self.td), ring[w % depth])

    monkeypatch.setattr(stream_fused._Engine, "paged_fill", bad_paged_fill)
    findings = contracts.run_contracts(
        points=[Point("stacked", "hbm_paged", 1, cases.TD)])
    f = _only(findings, "dma-ring-order")
    assert "still outstanding" in f.message


def test_dma_missing_site_fires(monkeypatch):
    """A paged state whose write-back never happens -> one finding."""
    monkeypatch.setattr(stream_fused._Engine, "write_back",
                        lambda self, i: None)
    findings = contracts.run_contracts(points=[PAGED_STACKED])
    f = _only(findings, "dma-missing-site")
    assert "write_back" in f.message


def test_hbm_alias_coverage_fires():
    """A captured paged launch with its aliases stripped -> one finding
    per unaliased state (stacked declares exactly one)."""
    (_, launch), = contracts.trace_point(PAGED_STACKED).launches
    doctored = dataclasses.replace(launch, aliases={})
    f = _only(contracts._check_launch(PAGED_STACKED, doctored),
              "hbm-alias-coverage")
    assert "not aliased" in f.message


def test_vmem_bytes_drift_fires():
    """Extra VMEM scratch the estimator does not know about -> drift."""
    (_, launch), = contracts.trace_point(PAGED_STACKED).launches
    doctored = dataclasses.replace(
        launch, scratch=[*launch.scratch,
                         pltpu.VMEM((8, 128), jnp.float32)])
    f = _only(contracts._check_launch(PAGED_STACKED, doctored),
              "vmem-bytes-drift")
    assert "stream_vmem_bytes" in f.message


def test_pingpong_parity_fires(monkeypatch):
    """A final-plane select decoupled from the write parity -> finding."""
    monkeypatch.setattr(stream_fused, "paged_final_plane", lambda t: 0)
    f = _only(contracts.check_parity_helpers(), "pingpong-parity")
    assert "final-plane" in f.message


def test_static_zero_states_fires():
    """A 'static' CellSpec that smuggles StateDefs past registration."""
    spec = dataclasses.replace(stream_fused.REGISTRY["gcrn"],
                               temporal="static")
    f = _only(contracts.check_registry_declarations({"fake_static": spec}),
              "static-zero-states")
    assert "fake_static" in f.message


def test_launch_assembly_error_fires():
    """A registered family without an analysis fixture IS a finding."""
    findings = contracts.run_contracts(
        registry={"mystery": stream_fused.REGISTRY["gcrn"]},
        points=[Point("mystery", "vmem", None, None)])
    f = _only(findings, "launch-assembly-error")
    assert "mystery" in f.message


# ========================================================== lint rules ==

def _snippet(tmp_path, rel, src):
    p = tmp_path / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(src))
    return rel


def _lint_one(tmp_path, rel, src, rule):
    findings = lint.run_lint(tmp_path,
                             files=[_snippet(tmp_path, rel, src)])
    return _only(findings, rule)


def test_stream_def_outside_registry_fires(tmp_path):
    f = _lint_one(tmp_path, "src/repro/rogue.py", """\
        def my_gcrn_stream_launcher(x):
            return x
        """, "stream-def-outside-registry")
    assert "my_gcrn_stream_launcher" in f.message and f.line == 1


def test_stream_def_ref_oracles_exempt(tmp_path):
    rel = _snippet(tmp_path, "src/repro/kernels/oracles.py", """\
        def gcrn_stream_ref(x):
            return x
        """)
    assert lint.run_lint(tmp_path, files=[rel]) == []


def test_single_kernel_body_fires(tmp_path):
    f = _lint_one(tmp_path, "src/repro/kernels/stream_fused.py", """\
        def first_kernel(x_ref):
            pass

        def second_kernel(y_ref):
            pass
        """, "single-kernel-body")
    assert "found 2" in f.message and f.line == 4


def test_mode_string_dispatch_fires(tmp_path):
    f = _lint_one(tmp_path, "examples/demo.py", """\
        run_stream(snaps, mode="v3")
        """, "mode-string-dispatch")
    assert 'mode="v3"' in f.message


def test_direct_stream_steps_fires(tmp_path):
    f = _lint_one(tmp_path, "benchmarks/bench.py", """\
        outs = ops.stream_steps(fam, *args)
        """, "direct-stream-steps")
    assert "stream_steps" in f.message


def test_broad_except_fires(tmp_path):
    f = _lint_one(tmp_path, "src/repro/fragile.py", """\
        try:
            launch()
        except Exception:
            pass
        """, "broad-except")
    assert "except Exception" in f.message and f.line == 3


def test_broad_except_allowlist_skipped(tmp_path):
    rel = _snippet(tmp_path, "src/repro/serve/engine.py", """\
        try:
            launch()
        except Exception:
            pass
        """)
    assert lint.run_lint(tmp_path, files=[rel]) == []


def test_mutable_default_arg_fires(tmp_path):
    f = _lint_one(tmp_path, "src/repro/leaky.py", """\
        def accumulate(x, seen=[]):
            seen.append(x)
            return seen
        """, "mutable-default-arg")
    assert "accumulate" in f.message


def test_jnp_in_kernel_body_fires(tmp_path):
    f = _lint_one(tmp_path, "src/repro/kernels/extra.py", """\
        def fancy_kernel(x_ref, o_ref):
            o_ref[...] = jnp.concatenate([x_ref[...], x_ref[...]])
        """, "jnp-in-kernel-body")
    assert "jnp.concatenate" in f.message and f.severity == "warning"


def test_jnp_outside_kernel_body_allowed(tmp_path):
    rel = _snippet(tmp_path, "src/repro/kernels/host.py", """\
        def pad_host(x):
            return jnp.concatenate([x, x])
        """)
    assert lint.run_lint(tmp_path, files=[rel]) == []


def test_syntax_error_fires(tmp_path):
    f = _lint_one(tmp_path, "src/repro/broken.py", """\
        def f(:
        """, "syntax-error")
    assert "unparseable" in f.message


def test_suppression_comment_waives(tmp_path):
    rel = _snippet(tmp_path, "src/repro/fragile.py", """\
        try:
            launch()
        except Exception:  # booster: ignore[broad-except]
            pass
        """)
    findings = lint.run_lint(tmp_path, files=[rel])
    assert _rules(findings) == {"broad-except"}
    report = core.Report()
    kept = core.apply_suppressions(findings, tmp_path, report)
    assert kept == [] and report.suppressed == 1


# ========================================================= drift rules ==

def test_plan_doc_drift_fires(tmp_path):
    """Un-backticking one field's table row de-documents it."""
    text = (core.repo_root() / "docs/api.md").read_text()
    assert "| `fault_plan` |" in text
    (tmp_path / "api.md").write_text(
        text.replace("| `fault_plan` |", "| fault_plan |"))
    f = _only(drift.check_plan_docs(tmp_path, api_md="api.md"),
              "plan-doc-drift")
    assert "`fault_plan`" in f.message and "no row" in f.message


def test_family_levels_drift_fires():
    fake = {**stream_fused.REGISTRY,
            "novel": stream_fused.REGISTRY["gcrn"]}
    f = _only(drift.check_family_levels(registry=fake),
              "family-levels-drift")
    assert "novel" in f.message


def test_ci_matrix_drift_fires():
    fake = {**stream_fused.REGISTRY,
            "novel": stream_fused.REGISTRY["gcrn"]}
    f = _only(drift.check_ci_matrix(core.repo_root(), registry=fake),
              "ci-matrix-drift")
    assert "novel" in f.message


def test_harness_case_drift_fires():
    """Both case-builder artifacts (tests/harness.py and the analyzer's
    own fixtures) must cover a newly registered family — one finding
    each."""
    fake = {**stream_fused.REGISTRY,
            "novel": stream_fused.REGISTRY["gcrn"]}
    findings = drift.check_harness_cases(core.repo_root(), registry=fake)
    assert [f.rule for f in findings] == ["harness-case-drift"] * 2
    assert {f.path for f in findings} == {"tests/harness.py",
                                          "src/repro/analysis/cases.py"}


def test_drift_clean_tree():
    assert drift.run_drift(core.repo_root()) == []


# ================================================= CLI / whole-analyzer ==

def test_rule_ids_unique_across_groups():
    total = (len(contracts.RULES) + len(lint.RULES) + len(drift.RULES))
    assert len(analysis.ALL_RULES) == total
    for rid, r in analysis.ALL_RULES.items():
        assert rid == r.id and r.group in core.GROUPS
        assert r.severity in ("error", "warning")


def test_select_rules():
    ids = core.select_rules(analysis.ALL_RULES, "lint,plan-doc-drift")
    assert "broad-except" in ids and "plan-doc-drift" in ids
    assert "dma-ring-order" not in ids
    with pytest.raises(SystemExit):
        core.select_rules(analysis.ALL_RULES, "no-such-rule")


def test_clean_tree_and_stable_json():
    """Acceptance: the shipped tree is analyzer-clean with ZERO
    suppressions, and the JSON report is byte-stable across runs."""
    r1 = analysis.run_all()
    assert r1.findings == [] and r1.suppressed == 0
    r2 = analysis.run_all()
    assert r1.to_json() == r2.to_json()
    assert '"findings": []' in r1.to_json()


def test_cli_exit_codes(tmp_path, capsys):
    from repro.analysis.__main__ import main
    assert main(["--rules", "drift", "--format", "json"]) == 0
    out = capsys.readouterr().out
    assert '"version": 1' in out
    assert main(["--list-rules"]) == 0
