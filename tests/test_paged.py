"""HBM-paged state residency (state_residency="hbm_paged").

The paging contract pinned here:

  1. EXACTNESS — for every stateful family, solo and batched (including
     ragged ``lengths``), an hbm_paged launch at ring depth 2 and 4 is
     BIT-IDENTICAL to the VMEM-resident launch: outputs and drained
     final states. Paging moves the store, never the math (every paged
     fill reproduces the resident cache columns window-by-window).
  2. CAPACITY — a store over the VMEM scratch budget is rejected under
     residency="vmem" with a hint to page, and RUNS under hbm_paged
     (matching the resident outputs computed under a roomier budget):
     the "larger than the old VMEM cap" unlock of the paging PR.
  3. ACCOUNTING — the plan-time estimator ``stream_vmem_bytes`` equals
     ``launch_scratch_bytes`` of the actually-assembled launch, for
     every family in resident, D-blocked, and paged (depth 2/4) layouts.
  4. NO FULL STORE — under paging, no family allocates a full
     ``(n_global, d_pad)`` (or ``(d_pad, d_pad)`` weights) plane in VMEM
     scratch: only ``td``-wide staging/ring windows transit VMEM, and
     the HBM store is aliased in-place (input_output_aliases).
  5. Static families have no state to page: kernel- and model-level
     rejection with the pinned message (plan-level lives in test_api.py).
"""
import contextlib
import dataclasses

import jax
import jax.numpy as jnp
import pytest

import harness
from repro import api
from repro.kernels import ops, stream_fused

STATEFUL = ("gcrn", "stacked", "evolve", "tgn")


def _assert_bitwise(got, want):
    ga, wa = jax.tree_util.tree_leaves(got), jax.tree_util.tree_leaves(want)
    assert len(ga) == len(wa)
    for g, w in zip(ga, wa):
        assert g.shape == w.shape and g.dtype == w.dtype
        assert jnp.array_equal(g, w), "paged output diverged from resident"


@contextlib.contextmanager
def _capture_launch(family):
    """Spy on the family's registry build to capture the assembled
    ``_Launch`` (and the build's padded args/kwargs) at trace time."""
    spec = stream_fused.REGISTRY[family]
    box = {}

    def spy(*a, **kw):
        launch = spec.build(*a, **kw)
        box["launch"], box["args"], box["kw"] = launch, a, kw
        return launch

    stream_fused.REGISTRY[family] = dataclasses.replace(spec, build=spy)
    stream_fused.stream_call.clear_cache()
    try:
        yield box
    finally:
        stream_fused.REGISTRY[family] = spec
        stream_fused.stream_call.clear_cache()


def _dims(family, box):
    """Recover the estimator's inputs from the captured (padded) build
    args — same shape arithmetic as the builds themselves."""
    a, kw = box["args"], box["kw"]
    td = kw["td"]
    if family == "gcrn":
        n, din, h0 = a[0].shape[2], a[4].shape[3], a[7]
        G, h = h0.shape[1], h0.shape[2]
        return dict(g_rows=G, n_pad=n, din=din,
                    d_pad=stream_fused._round_up(h, td or h))
    if family == "stacked":
        n, h0, w_gcn = a[0].shape[2], a[6], a[7]
        G, h = h0.shape[1], h0.shape[2]
        return dict(g_rows=G, n_pad=n, dmid=w_gcn.shape[1],
                    d_pad=stream_fused._round_up(h, td or h))
    if family == "evolve":
        n, w0 = a[0].shape[2], a[5]
        return dict(n_pad=n, n_layers=w0.shape[1], d_pad=w0.shape[2])
    if family == "tgn":
        n, mem0 = a[0].shape[2], a[6]
        G, h = mem0.shape[1], mem0.shape[2]
        return dict(g_rows=G, n_pad=n,
                    d_pad=stream_fused._round_up(h, td or h))
    if family == "static_gcn":
        n, w = a[0].shape[2], a[4]
        return dict(n_pad=n, n_layers=w.shape[0], d_pad=w.shape[1])
    raise KeyError(family)


# ------------------------------------------------------- exactness ----

@pytest.mark.parametrize("family", STATEFUL)
@pytest.mark.parametrize("depth", [2, 4])
def test_paged_solo_bitwise(family, depth):
    args, _, _ = harness.stream_kernel_case(family, seed=3, T=3)
    want = ops.stream_steps(family, *args, tn=32, td=8)
    got = ops.stream_steps(family, *args, tn=32, td=8,
                           state_residency="hbm_paged", buffer_depth=depth)
    _assert_bitwise(got, want)


@pytest.mark.parametrize("family", STATEFUL)
@pytest.mark.parametrize("depth", [2, 4])
def test_paged_batched_ragged_bitwise(family, depth):
    args, _, _ = harness.stream_kernel_case(family, seed=11, T=4, B=3)
    for lengths in (None, (4, 2, 0)):
        want = ops.stream_steps_batched(family, *args, tn=32, td=8,
                                        lengths=lengths)
        got = ops.stream_steps_batched(family, *args, tn=32, td=8,
                                       lengths=lengths,
                                       state_residency="hbm_paged",
                                       buffer_depth=depth)
        _assert_bitwise(got, want)


def test_paged_through_plan_api():
    """plan(state_residency=, buffer_depth=) reaches the kernel through
    run_arrays — solo and batched-ragged — bit-identically."""
    args, _, _ = harness.stream_kernel_case("gcrn", seed=5, T=3)
    base = api.run_arrays(api.plan(family="gcrn", tn=32, td=8), *args)
    paged = api.run_arrays(
        api.plan(family="gcrn", tn=32, td=8,
                 state_residency="hbm_paged", buffer_depth=4), *args)
    _assert_bitwise(paged, base)

    argsB, _, _ = harness.stream_kernel_case("tgn", seed=6, T=4, B=3)
    pb = dict(family="tgn", tn=32, td=8, batch=3, lengths=(4, 2, 1))
    baseB = api.run_arrays(api.plan(**pb), *argsB)
    pagedB = api.run_arrays(
        api.plan(**pb, state_residency="hbm_paged", buffer_depth=2), *argsB)
    _assert_bitwise(pagedB, baseB)


# -------------------------------------------------------- capacity ----

def test_oversized_store_runs_only_paged(monkeypatch):
    """A state store over the VMEM budget must refuse to launch resident
    (with a hint to page) and run paged — matching the resident outputs
    computed under the roomy budget."""
    args, _, _ = harness.stream_kernel_case("gcrn", seed=9, T=3)
    want = ops.stream_steps("gcrn", *args, tn=32, td=8)
    with _capture_launch("gcrn") as box:
        ops.stream_steps("gcrn", *args, tn=32, td=8)
        resident_bytes = stream_fused.launch_scratch_bytes(box["launch"])
    with _capture_launch("gcrn") as box:
        ops.stream_steps("gcrn", *args, tn=32, td=8,
                         state_residency="hbm_paged", buffer_depth=2)
        paged_bytes = stream_fused.launch_scratch_bytes(box["launch"])
    assert paged_bytes < resident_bytes  # paging must actually shrink VMEM
    budget = (paged_bytes + resident_bytes) // 2
    monkeypatch.setattr(stream_fused, "VMEM_BUDGET_BYTES", budget)
    stream_fused.stream_call.clear_cache()
    try:
        with pytest.raises(ValueError, match="byte budget.*hbm_paged"):
            ops.stream_steps("gcrn", *args, tn=32, td=8)
        got = ops.stream_steps("gcrn", *args, tn=32, td=8,
                               state_residency="hbm_paged", buffer_depth=2)
        _assert_bitwise(got, want)
    finally:
        monkeypatch.undo()
        stream_fused.stream_call.clear_cache()


# ------------------------------------------------------ accounting ----

@pytest.mark.parametrize("family", STATEFUL + ("static_gcn",))
@pytest.mark.parametrize("residency,td,depth", [
    ("vmem", None, 2),       # fully resident
    ("vmem", 8, 2),          # D-blocked resident
    ("hbm_paged", 8, 2),     # double-buffered paging
    ("hbm_paged", 8, 4),     # quad-buffered paging
])
def test_scratch_byte_accounting(family, residency, td, depth):
    """Plan-time VMEM estimate == actual assembled pltpu.VMEM scratch."""
    if family == "static_gcn":
        if residency == "hbm_paged":
            pytest.skip("static_gcn cannot page (pinned below)")
        T = 1
    else:
        T = 3
    args, _, _ = harness.stream_kernel_case(family, seed=2, T=T)
    with _capture_launch(family) as box:
        kw = ({} if residency == "vmem"
              else dict(state_residency=residency, buffer_depth=depth))
        ops.stream_steps(family, *args, tn=32, td=td, **kw)
        actual = stream_fused.launch_scratch_bytes(box["launch"])
        est = stream_fused.stream_vmem_bytes(
            family, td=td, residency=residency, depth=depth,
            **_dims(family, box))
    assert actual == est, (
        f"{family}/{residency}/td={td}/depth={depth}: "
        f"assembled {actual} VMEM bytes, estimator says {est}")


@pytest.mark.parametrize("family", STATEFUL)
def test_no_full_store_in_vmem_when_paged(family):
    """Under paging no family may allocate a full-width state plane in
    VMEM scratch — only (rows, td) staging/ring windows — and the HBM
    store must be aliased in-place (zero-copy across the launch)."""
    args, _, _ = harness.stream_kernel_case(family, seed=4, T=3)
    with _capture_launch(family) as box:
        ops.stream_steps(family, *args, tn=32, td=8,
                         state_residency="hbm_paged", buffer_depth=2)
        launch = box["launch"]
        dims = _dims(family, box)
    d_pad = dims["d_pad"]
    assert d_pad > 8, "case must be D-blocked for the assertion to bite"
    full_rows = dims.get("g_rows", d_pad)  # weights plane is (d_pad, d_pad)
    for s in launch.scratch:
        if getattr(s, "memory_space", None) != stream_fused.pltpu.VMEM:
            continue
        assert s.shape[-2:] != (full_rows, d_pad), (
            f"{family}: full ({full_rows}, {d_pad}) state plane in VMEM "
            f"scratch under hbm_paged: {s.shape}")
    assert launch.aliases, (
        f"{family}: paged store must alias input->output (in-place HBM)")
    assert launch.meta.paged and launch.meta.depth == 2


# ------------------------------------------------- benchmark ledger ----

def test_write_stream_bench_dedupes_by_plan_signature(tmp_path):
    """Re-running a planned benchmark config replaces its ledger row
    instead of accumulating a sibling duplicate, even when the row name
    embeds run-varying counters (T8 vs T16); rows whose plans genuinely
    differ (e.g. buffer_depth) stay distinct, and un-planned rows keep
    keying by exact name."""
    import json

    from benchmarks.common import write_stream_bench

    path = tmp_path / "bench.json"
    plan_d2 = api.plan(family="gcrn", td=8, state_residency="hbm_paged",
                       buffer_depth=2).as_dict()
    plan_d4 = api.plan(family="gcrn", td=8, state_residency="hbm_paged",
                       buffer_depth=4).as_dict()
    write_stream_bench([("kernel/gcrn_paged_d2_T8", 10.0, "w=1"),
                        ("kernel/gcrn_paged_d4_T8", 11.0, "w=1")],
                       {"kernel/gcrn_paged_d2_T8": plan_d2,
                        "kernel/gcrn_paged_d4_T8": plan_d4}, path=path)
    # same configs re-run at a different sweep length: rows REPLACED
    write_stream_bench([("kernel/gcrn_paged_d2_T16", 9.0, "w=2")],
                       {"kernel/gcrn_paged_d2_T16": plan_d2}, path=path)
    # un-planned rows: keyed by exact name, overwrite on re-run
    write_stream_bench([("kernel/xla_ref", 5.0, "")], path=path)
    write_stream_bench([("kernel/xla_ref", 6.0, "")], path=path)
    rows = {r["name"]: r for r in json.loads(path.read_text())["rows"]}
    assert set(rows) == {"kernel/gcrn_paged_d2_T16",
                         "kernel/gcrn_paged_d4_T8", "kernel/xla_ref"}
    assert rows["kernel/gcrn_paged_d2_T16"]["us_per_call"] == 9.0
    assert rows["kernel/gcrn_paged_d2_T16"]["plan"]["buffer_depth"] == 2
    assert rows["kernel/xla_ref"]["us_per_call"] == 6.0


# ---------------------------------------------------- static family ----

def test_static_gcn_rejects_paging():
    args, _, _ = harness.stream_kernel_case("static_gcn", seed=1)
    with pytest.raises(ValueError, match="no recurrent store to page"):
        ops.stream_steps("static_gcn", *args, tn=32, td=8,
                         state_residency="hbm_paged", buffer_depth=2)


def test_static_gcn_model_rejects_paging():
    from repro.core.gcn import StaticGCN
    with pytest.raises(ValueError, match="no recurrent store to page"):
        StaticGCN._check_residency("hbm_paged", None)
    with pytest.raises(ValueError, match="no recurrent store to page"):
        StaticGCN._check_residency("vmem", 2)
    StaticGCN._check_residency("vmem", None)  # default is fine
